"""Tests for the `repro.calib` subsystem (ISSUE 4): blind
measurement-driven calibration on a VirtualChip, the serializable
CalibrationSnapshot, snapshot-baked lowering through exec/api, the
static-calibration fused-group unlock, and the serve-time drift monitor
hot-swap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, calib
from repro.core.analog import AnalogConfig, analog_linear_init
from repro.core.noise import NOISELESS, NoiseConfig
from repro.exec.lower import lower_layer, lower_stack, plan_with_offsets
from repro.exec.run import dispatch_count, reset_dispatch_count, run, \
    run_layer
from repro.models import ecg as ECG

KEY = jax.random.PRNGKey(3)

ECG_KW = dict(
    epilogues=["relu_shift", "relu_shift", "none"],
    flatten_outs=[True, False, False], input_domain="codes",
)
ECG_NAMES = ("conv", "fc1", "fc2")


def _ecg_setup(seed=0, n=32):
    cfg = ECG.ECGConfig()
    params = ECG.ecg_init(jax.random.PRNGKey(seed), cfg)
    x = jnp.round(
        jax.random.uniform(jax.random.PRNGKey(seed + 1), (n, 2, 126)) * 31
    )
    cols = ECG._im2col(x, cfg.conv_taps, cfg.conv_stride)
    return cfg, params, x, cols


class TestVirtualChip:
    def test_measure_is_blind_and_shaped(self):
        chip = calib.VirtualChip(KEY, 200, 16, noise=NoiseConfig())
        adc = chip.measure(jnp.zeros((200, 16)), jnp.zeros((5, 200)))
        assert adc.shape == (5, chip.n_chunks, 16)
        assert chip.measurements == 1

    def test_measure_clips_to_representable_codes(self):
        """The interface can only express 6-bit weights / 5-bit events:
        out-of-range requests saturate like the hardware registers."""
        chip = calib.VirtualChip(KEY, 128, 4, noise=NOISELESS)
        big = chip.measure(jnp.full((128, 4), 1000.0),
                           jnp.full((1, 128), 1000.0), gain=0.001)
        leg = chip.measure(jnp.full((128, 4), 63.0),
                           jnp.full((1, 128), 31.0), gain=0.001)
        np.testing.assert_array_equal(np.asarray(big), np.asarray(leg))

    def test_noiseless_measure_matches_oracle_plan(self):
        """On a noiseless chip one accumulated measurement IS the
        faithful executor output up to fp32 summation order at exact ADC
        rounding ties (the chip batches its chunk passes, the
        deterministic executor chunk-scans): every element within 1 LSB,
        almost all exact."""
        from repro.core.analog import analog_matmul

        p = analog_linear_init(jax.random.PRNGKey(1), 200, 8,
                               noise=NoiseConfig(readout_std=0.0))
        chip = calib.VirtualChip.from_params(
            p, KEY, noise=NoiseConfig(readout_std=0.0))
        w_code = jnp.round(jax.random.normal(KEY, (200, 8)) * 20)
        a = jnp.round(jax.random.uniform(KEY, (3, 200)) * 31)
        got = np.asarray(chip.measure(w_code, a, gain=0.02).sum(axis=-2))
        want = np.asarray(analog_matmul(
            a, jnp.asarray(np_effective(p, w_code)), 0.02,
            p["fpn"].get("chunk_offset"), None,
            AnalogConfig(noise=NoiseConfig(readout_std=0.0)),
        ))
        assert np.abs(got - want).max() <= 1.0
        assert (got == want).mean() > 0.7


def np_effective(params, w_code):
    from repro.core import noise as noise_lib

    return noise_lib.effective_weight(w_code, params.get("fpn", {}))


class TestBlindRecovery:
    """Acceptance: with DEFAULT NoiseConfig magnitudes, offset nulling +
    gain fit recover the hidden fixed pattern to sub-LSB residual - the
    routines only ever touch chip.measure()."""

    @pytest.mark.parametrize("mode,k,n", [("full", 200, 48),
                                          ("rank1", 256, 32)])
    def test_sub_lsb_recovery(self, mode, k, n):
        chip = calib.VirtualChip(
            jax.random.fold_in(KEY, hash(mode) % 97), k, n,
            noise=NoiseConfig(mode=mode),
        )
        rec = calib.calibrate_chip(chip)
        truth = chip.oracle()
        off_res = np.abs(np.asarray(
            rec.chunk_offset - truth["chunk_offset"]
        ))
        assert off_res.max() < 0.5          # sub-LSB, every (chunk, col)
        assert (off_res ** 2).mean() ** 0.5 < 0.2
        rel = np.abs(np.asarray(
            (rec.gain_table - truth["gain_table"]) / truth["gain_table"]
        ))
        assert rel.max() < 0.03             # ~2% spread fitted to <3%

    def test_repeats_average_readout_noise(self):
        """More repeats -> smaller offset residual (the averaging claim,
        not just a lucky seed)."""
        res = {}
        for r in (4, 64):
            chip = calib.VirtualChip(KEY, 128, 32, noise=NoiseConfig())
            off = calib.null_offsets(chip, repeats=r)
            res[r] = float(jnp.sqrt(jnp.mean(
                (off - chip.oracle()["chunk_offset"]) ** 2
            )))
        assert res[64] < res[4]


class TestSnapshotRoundTrip:
    def test_save_load_bit_exact(self, tmp_path):
        cfg, params, _, cols = _ecg_setup()
        snap = calib.calibrate_model(
            ECG.ecg_module_spec(cfg), params, KEY,
            acfg=AnalogConfig(), sample=cols,
        )
        path = tmp_path / "chip.npz"
        snap.save(path)
        back = calib.CalibrationSnapshot.load(path)
        assert back.version == snap.version
        assert set(back.layers) == set(snap.layers)
        a, b = jax.tree.leaves(snap), jax.tree.leaves(back)
        assert len(a) == len(b)
        for la, lb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            assert la.dtype == lb.dtype

    def test_load_rejects_unknown_version(self, tmp_path):
        snap = calib.CalibrationSnapshot(source="t")
        path = tmp_path / "v.npz"
        snap.save(path)
        import numpy as onp

        with onp.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        arrays["__version__"] = onp.asarray("repro-calib-v0")
        with open(path, "wb") as f:
            onp.savez(f, **arrays)
        with pytest.raises(ValueError, match="format"):
            calib.CalibrationSnapshot.load(path)


class TestMeshInvariance:
    def test_calibration_independent_of_mesh(self):
        """Property (mirrors the fixed-pattern one): the chip samples its
        hidden pattern from the LOGICAL tile grid and the routines are
        pure functions of measure() results, so a calibration measured
        under an active mesh is identical to one measured without."""
        from repro.distributed import sharding as shd

        def measure_once():
            chip = calib.VirtualChip(KEY, 256, 32, noise=NoiseConfig())
            return calib.calibrate_chip(
                chip, offset_repeats=8, gain_repeats=2
            )

        r1 = measure_once()
        if len(jax.devices()) >= 4:
            with shd.use_mesh(jax.make_mesh((2, 2), ("data", "model"))):
                r2 = measure_once()
        else:
            with shd.use_mesh(jax.make_mesh((1, 1), ("data", "model"))):
                r2 = measure_once()
        for a, b in zip(jax.tree.leaves(r1), jax.tree.leaves(r2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCalibratedLowering:
    def test_ecg_calibrated_matches_oracle_within_noise(self):
        """Acceptance: the plan baked from blind measurement behaves like
        the plan baked from ground truth - classification agreement plus
        logit agreement within the uncompensatable per-synapse spread."""
        cfg, params, _, cols = _ecg_setup(n=64)
        acfg = AnalogConfig()
        snap = calib.calibrate_model(
            ECG.ecg_module_spec(cfg, epilogue="relu_shift"), params,
            jax.random.fold_in(KEY, 1),
        )
        lp = [params[n] for n in ECG_NAMES]
        plan_oracle = lower_stack(lp, acfg, **ECG_KW)
        plan_cal = lower_stack(
            lp, acfg, calibs=[snap.layer(n) for n in ECG_NAMES], **ECG_KW
        )
        yo, yc = run(plan_oracle, cols), run(plan_cal, cols)
        agree = float((yo.argmax(-1) == yc.argmax(-1)).mean())
        assert agree >= 0.9
        rel = float(jnp.abs(yo - yc).mean() / jnp.sqrt((yo ** 2).mean()))
        assert rel < 0.15
        # same static schedule: calibrated replay costs the same dispatches
        assert plan_cal.expected_dispatches == \
            plan_oracle.expected_dispatches
        assert (plan_cal.mega is None) == (plan_oracle.mega is None)

    def test_compile_calibration_kw_stack(self):
        cfg, params, x, cols = _ecg_setup()
        spec = ECG.ecg_module_spec(cfg, epilogue="relu_shift")
        acfg = AnalogConfig()
        snap = calib.calibrate_model(spec, params,
                                     jax.random.fold_in(KEY, 2))
        model = api.compile(spec, params, acfg, calibration=snap)
        assert model.calibration is snap
        want = run(lower_stack(
            [params[n] for n in ECG_NAMES], acfg,
            calibs=[snap.layer(n) for n in ECG_NAMES], **ECG_KW,
        ), cols)
        np.testing.assert_array_equal(
            np.asarray(model.run_stack(cols)), np.asarray(want)
        )
        # relower keeps the calibration (one weight update, same chip)
        again = model.relower(params)
        np.testing.assert_array_equal(
            np.asarray(again.run_stack(cols)), np.asarray(want)
        )

    def test_uncovered_layers_keep_oracle_bake(self):
        cfg, params, _, cols = _ecg_setup()
        acfg = AnalogConfig()
        snap = calib.CalibrationSnapshot()      # empty: nothing measured
        model = api.compile(ECG.ecg_module_spec(cfg, epilogue="relu_shift"),
                            params, acfg, calibration=snap)
        want = api.compile(ECG.ecg_module_spec(cfg, epilogue="relu_shift"),
                           params, acfg)
        np.testing.assert_array_equal(
            np.asarray(model.run_stack(cols)),
            np.asarray(want.run_stack(cols)),
        )

    def test_group_member_output_not_rescaled_by_joining(self):
        """Joining a shared-encoding group only coarsens the member's
        input LSB - it must NOT rescale the output (dequant happens at
        the LSB the codes were actually encoded with)."""
        p = analog_linear_init(KEY, 256, 16, noise=NOISELESS)
        p = dict(p, a_scale=jnp.asarray(0.01, jnp.float32))
        static = AnalogConfig(noise=NOISELESS, act_calib="static")
        x = jax.random.normal(KEY, (8, 256)) * 0.2
        solo = run_layer(lower_layer(p, static), x, static)
        grouped = run_layer(lower_layer(p, static, calib=(
            calib.LayerCalibration(
                a_scale=jnp.asarray(0.01, jnp.float32),
                a_scale_in=jnp.asarray(0.07, jnp.float32),
            ))), x, static)
        # same linear map, only quantization resolution differs
        rel = float(jnp.abs(solo - grouped).mean()
                    / (jnp.abs(solo).mean() + 1e-9))
        assert rel < 0.5       # NOT the ~7x attenuation of a rescale

    def test_scales_only_record_keeps_oracle_fixed_pattern(self):
        """A record carrying only activation scales (e.g. built by
        share_group_input_scale with explicit scales) must not silently
        bake an ideal chip: unmeasured quantities fall back to the
        oracle params['fpn']."""
        p = analog_linear_init(KEY, 256, 16, noise=NoiseConfig())
        rec = calib.LayerCalibration(
            a_scale=jnp.asarray(0.05, jnp.float32))
        lp = lower_layer(p, AnalogConfig(act_calib="static"), calib=rec)
        want = lower_layer(p, AnalogConfig(act_calib="static"))
        np.testing.assert_array_equal(np.asarray(lp.w_eff),
                                      np.asarray(want.w_eff))
        np.testing.assert_array_equal(np.asarray(lp.chunk_offset),
                                      np.asarray(want.chunk_offset))
        np.testing.assert_allclose(float(lp.a_scale), 0.05)

    def test_gain_table_shape_mismatch_raises(self):
        p = analog_linear_init(KEY, 256, 16, noise=NoiseConfig())
        bad = calib.LayerCalibration(
            gain_table=jnp.ones((3, 16), jnp.float32)   # 256 rows = 2 chunks
        )
        with pytest.raises(ValueError, match="gain_table"):
            lower_layer(p, AnalogConfig(), calib=bad)


class TestFusedStaticUnlock:
    """Acceptance: lower_fused accepts differing static a_scales when a
    snapshot provides the group's shared input scale (a_scale_in) -
    bit-exact vs unfused, dispatch count unchanged."""

    def _group(self):
        ps = [analog_linear_init(jax.random.fold_in(KEY, i), 256, 32,
                                 noise=NoiseConfig()) for i in range(3)]
        scales = [0.01, 0.07, 0.03]
        ps = [dict(p, a_scale=jnp.asarray(s, jnp.float32))
              for p, s in zip(ps, scales)]
        names = [f"l{i}" for i in range(3)]
        snap = calib.CalibrationSnapshot()
        for n, p in zip(names, ps):
            chip = calib.VirtualChip.from_params(
                p, jax.random.fold_in(KEY, 7))
            snap = snap.with_layer(n, calib.calibrate_chip(
                chip, offset_repeats=16, gain_repeats=2))
        snap = calib.share_group_input_scale(
            snap, names, scales=[p["a_scale"] for p in ps])
        return ps, names, snap

    def test_bit_exact_vs_unfused_and_one_dispatch(self):
        from repro.exec.lower import lower_fused

        ps, names, snap = self._group()
        static = AnalogConfig(act_calib="static")
        calibs = [snap.layer(n) for n in names]
        fused = lower_fused(ps, static, calibs=calibs)
        # ONE shared encoding LSB (widest member) for quant AND dequant
        np.testing.assert_allclose(float(fused.a_scale_in), 0.07)
        np.testing.assert_allclose(float(fused.a_scale), 0.07)
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        reset_dispatch_count()
        got = run_layer(fused, x, static)
        assert dispatch_count() == 1            # unchanged vs same-scale
        outs = []
        for p, c in zip(ps, calibs):
            outs.append(run_layer(
                lower_layer(p, static, calib=c), x, static))
        want = jnp.concatenate(outs, axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_differing_scales_still_raise_without_calibration(self):
        from repro.exec.lower import lower_fused

        ps, _, _ = self._group()
        with pytest.raises(ValueError, match="a_scale"):
            lower_fused(ps, AnalogConfig(act_calib="static"))

    def test_lower_tree_fuses_qkv_under_static_with_snapshot(self):
        from repro.models import attention as A

        p = A.attention_init(KEY, 64, 4, 2, 16, noise=NoiseConfig())
        p["wk"] = dict(p["wk"], a_scale=p["wk"]["a_scale"] * 7.0)
        static = AnalogConfig(act_calib="static")
        names = ["wq", "wk", "wv"]
        snap = calib.CalibrationSnapshot()
        for i, n in enumerate(names):
            chip = calib.VirtualChip.from_params(
                p[n], jax.random.fold_in(KEY, 20 + i))
            snap = snap.with_layer(n, calib.calibrate_chip(
                chip, offset_repeats=16, gain_repeats=2))
        snap = calib.share_group_input_scale(
            snap, names, scales=[p[n]["a_scale"] for n in names])
        lowered = api.lower_tree(p, static, calibration=snap)
        assert "_qkv_plan" in lowered           # static fusion unlocked
        # ... and attention consumes it (the a_scale_in marker)
        x = jax.random.normal(KEY, (2, 8, 64)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None],
                               (2, 8))
        kw = dict(positions=pos, acfg=static, n_heads=4, n_kv_heads=2,
                  head_dim=16, rope_theta=1e4)
        reset_dispatch_count()
        A.attention_apply(lowered, x, **kw)
        n_fused = dispatch_count()
        # per-layer lowering from the SAME snapshot: 2 more dispatches
        per_layer = {k: (dict(v, _plan=lower_layer(
            p[k], static, calib=snap.layer(k)))
            if k in names else v) for k, v in p.items()}
        reset_dispatch_count()
        want, _ = A.attention_apply(per_layer, x, **kw)
        assert dispatch_count() == n_fused + 2
        got, _ = A.attention_apply(lowered, x, **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_without_group_scale_no_static_fusion(self):
        """A snapshot that measured the members separately (no shared
        a_scale_in) must NOT unlock static fusion."""
        from repro.models import attention as A

        p = A.attention_init(KEY, 64, 4, 2, 16, noise=NoiseConfig())
        snap = calib.CalibrationSnapshot()
        for i, n in enumerate(["wq", "wk", "wv"]):
            chip = calib.VirtualChip.from_params(
                p[n], jax.random.fold_in(KEY, 30 + i))
            snap = snap.with_layer(n, calib.calibrate_chip(
                chip, offset_repeats=8, gain_repeats=2))
        lowered = api.lower_tree(
            p, AnalogConfig(act_calib="static"), calibration=snap)
        assert "_qkv_plan" not in lowered


class TestDriftMonitorHotSwap:
    def test_stack_offset_swap_keeps_treedef_and_cache(self):
        cfg, params, _, cols = _ecg_setup()
        spec = ECG.ecg_module_spec(cfg, epilogue="relu_shift")
        acfg = AnalogConfig()
        chips = calib.model_chips(spec, params, KEY)
        snap = calib.calibrate_model(spec, params, KEY, chips=chips)
        model = api.compile(spec, params, acfg, calibration=snap)
        plan = model.lower()
        f = jax.jit(lambda pl, c: run(pl, c))
        y0 = f(plan, cols)
        # offsets drift on every device; the monitor detects + re-nulls
        mon = calib.DriftMonitor(chips, snap, threshold_lsb=0.5)
        assert mon.maybe_refresh() is None      # stable: no refresh
        for i, c in enumerate(chips.values()):
            c.apply_drift(jax.random.fold_in(KEY, 50 + i), 2.0)
        assert mon.drift_lsb() > 0.5
        fresh = mon.maybe_refresh()
        assert fresh is not None and mon.refreshes == 1
        swapped = model.with_calibration(fresh).lower()
        assert jax.tree_util.tree_structure(swapped) == \
            jax.tree_util.tree_structure(plan)
        y1 = f(swapped, cols)
        assert f._cache_size() == 1             # hot swap: NO recompile
        # the swapped plan tracks the drifted device to sub-LSB again
        for name, lp in zip(ECG_NAMES, swapped.layers):
            res = jnp.abs(lp.chunk_offset
                          - chips[name].oracle()["chunk_offset"])
            assert float(res.max()) < 0.5
        # and actually changed the computation (drift was real)
        assert not bool((y0 == y1).all())

    def test_refresh_keeps_gains_and_scales(self):
        chip = calib.VirtualChip(KEY, 128, 8, noise=NoiseConfig())
        rec = calib.calibrate_chip(chip, offset_repeats=16,
                                   gain_repeats=2)
        snap = calib.CalibrationSnapshot(layers={"l": rec}) \
            .with_layer("l", rec.replace(a_scale=jnp.asarray(0.5)))
        mon = calib.DriftMonitor({"l": chip}, snap, threshold_lsb=0.1)
        chip.apply_drift(KEY, 1.0)
        fresh = mon.maybe_refresh()
        assert fresh is not None
        np.testing.assert_array_equal(
            np.asarray(fresh.layer("l").gain_table),
            np.asarray(rec.gain_table),
        )
        np.testing.assert_allclose(float(fresh.layer("l").a_scale), 0.5)

    def test_serve_engine_recalibrates_between_batches(self):
        from repro.configs.base import ArchConfig, RunConfig
        from repro.models import transformer as T
        from repro.serve.engine import Request, ServeEngine

        cfg = ArchConfig("t-drift", "dense", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab_size=256)
        params = T.lm_init(KEY, cfg)
        run_cfg = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        spec = T.lm_module_spec(cfg, params)
        chips = calib.model_chips(spec, params, KEY)
        assert chips                            # lm_head at least
        snap = calib.calibrate_model(spec, params, KEY, chips=chips,
                                     offset_repeats=16, gain_repeats=2)
        mon = calib.DriftMonitor(chips, snap, threshold_lsb=0.5)
        eng = ServeEngine(cfg, run_cfg, params, batch_size=2, max_len=32,
                          calibration=snap, drift_monitor=mon)
        td0 = jax.tree_util.tree_structure(eng.params)
        prompt = np.arange(6) % cfg.vocab_size
        r1 = eng.serve([Request(0, prompt, 4)])[0]
        assert mon.refreshes == 0
        for i, c in enumerate(chips.values()):
            c.apply_drift(jax.random.fold_in(KEY, 70 + i), 2.0)
        r2 = eng.serve([Request(1, prompt, 4)])[0]
        assert mon.refreshes == 1               # drift detected + swapped
        assert jax.tree_util.tree_structure(eng.params) == td0
        assert r2.output is not None and len(r2.output) == 4


class TestECGNoiseModeAudit:
    """Satellite: the ECG config REQUESTS the documented full per-synapse
    map explicitly; ecg_init no longer silently upgrades the mode."""

    def test_default_config_is_full_map(self):
        assert ECG.ECGConfig().noise.mode == "full"

    def test_init_honors_requested_mode(self):
        p_full = ECG.ecg_init(KEY, ECG.ECGConfig())
        assert p_full["conv"]["fpn"]["gain"].shape == (128, 8)
        rank1 = ECG.ECGConfig(noise=NoiseConfig())     # explicit rank1
        p_r1 = ECG.ecg_init(KEY, rank1)
        assert "gain" not in p_r1["conv"]["fpn"]
        assert p_r1["conv"]["fpn"]["row_gain"].shape == (128,)

    def test_spec_declares_codes_domain_for_relu_shift(self):
        spec = ECG.ecg_module_spec(ECG.ECGConfig(), epilogue="relu_shift")
        assert spec.input_domain == "codes"
        assert spec.layer_names() == ("conv", "fc1", "fc2")


class TestPlanOffsetSwapHelpers:
    def test_plan_with_offsets_rejects_shape_mismatch(self):
        cfg, params, _, _ = _ecg_setup()
        plan = lower_stack([params[n] for n in ECG_NAMES],
                           AnalogConfig(), **ECG_KW)
        with pytest.raises(ValueError, match="shape"):
            plan_with_offsets(
                plan, [jnp.zeros((1, 1))] * len(plan.layers))

    def test_swap_requires_existing_offsets(self):
        from repro.exec.lower import layer_with_offsets

        p = analog_linear_init(KEY, 128, 8, noise=NOISELESS)
        lp = lower_layer(p, AnalogConfig(noise=NOISELESS))
        assert lp.chunk_offset is None
        with pytest.raises(ValueError, match="offset"):
            layer_with_offsets(lp, jnp.zeros((1, 8)))
