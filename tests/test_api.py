"""Tests for the `repro.api` front door (ISSUE 2): spec -> compile ->
CompiledModel, whole-block plans with fused QKV dispatch groups,
mesh-sharded pre-lowering (plan leaves as first-class shardables), the
HIL-through-compile train contract, and the deprecation shims over the
legacy entrypoints (bit-exact by construction)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.exec as E
from repro import api
from repro.configs.base import ArchConfig, RunConfig
from repro.core.analog import AnalogConfig, analog_linear_init
from repro.core.noise import NOISELESS, NoiseConfig
from repro.distributed import sharding as shd
from repro.exec.run import dispatch_count, reset_dispatch_count
from repro.models import ecg as ECG
from repro.models import transformer as T

KEY = jax.random.PRNGKey(7)
ACFG = AnalogConfig(noise=NOISELESS)

TINY = ArchConfig("t-api", "dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256)


def _mk(in_dim=256, out_dim=64, noise=NOISELESS, seed=0):
    return analog_linear_init(
        jax.random.PRNGKey(seed), in_dim, out_dim, noise=noise
    )


def _lm_batch(cfg, b=2, s=8, seed=1):
    k = jax.random.PRNGKey(seed)
    return {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size)}


@pytest.fixture()
def mesh11():
    with shd.use_mesh(jax.make_mesh((1, 1), ("data", "model"))) as m:
        yield m


class TestCompileStack:
    def test_linear_spec_compile_apply(self):
        p = _mk()
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        model = api.compile(api.linear_spec(256, 64), p, ACFG)
        y = model.apply(x)
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(api.apply_linear(p, x, ACFG))
        )
        # the compiled artifact is a replayable AnalogPlan
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(E.run(model.lower(), x))
        )

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="declares"):
            api.compile(api.linear_spec(128, 64), _mk(), ACFG)

    def test_digital_stack_matches_analog_contract(self):
        """Digital compile runs the reference path with the same
        inter-layer ReLU glue the plan executor uses."""
        ps = {"a": _mk(seed=1, out_dim=256), "b": _mk(seed=2)}
        spec = api.ModuleSpec(name="2fc", kind="stack", layers=(
            api.LayerSpec("a", 256, 256), api.LayerSpec("b", 256, 64),
        ))
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        y = api.compile(spec, ps, AnalogConfig(mode="digital")).apply(x)
        want = jnp.maximum(
            x @ ps["a"]["w"], 0.0
        ) @ ps["b"]["w"]
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_relower_tracks_new_params(self):
        p = _mk()
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        model = api.compile(api.linear_spec(256, 64), p, ACFG)
        p2 = dict(p, w=p["w"] * 2.0)
        y2 = model.relower(p2).apply(x)
        assert not np.array_equal(np.asarray(model.apply(x)),
                                  np.asarray(y2))


class TestCompileTree:
    def test_lm_plan_bit_exact_and_fewer_dispatches(self):
        """The pre-lowered LM tree (stacked layers lowered under vmap,
        QKV fused into one dispatch group) computes exactly the per-call
        function with fewer analog dispatches per trace."""
        params = T.lm_init(KEY, TINY)
        run = RunConfig(analog=AnalogConfig(mode="analog_faithful"))
        batch = _lm_batch(TINY)
        reset_dispatch_count()
        want, _, _ = T.lm_apply(params, batch, TINY, run)
        n_raw = dispatch_count()

        model = api.compile(T.lm_module_spec(TINY, params), params, run)
        lowered = model.lower()
        g0 = lowered["layers"]["l0"]
        assert "_qkv_plan" in g0["attn"] and "_plan" in g0["attn"]["wo"]
        assert "_plan" not in g0["attn"]["wq"]     # fused group elides it
        reset_dispatch_count()
        got, _, _ = model.apply(batch)
        n_plan = dispatch_count()
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
        # per group: QKV 3 -> 1; totals include wo + mlp + lm_head
        assert n_plan < n_raw

    def test_stacked_plans_flow_through_scan(self):
        """Scan-stacked layer plans carry a leading group axis on every
        array leaf (the legacy prelower_tree skipped stacked layers)."""
        params = T.lm_init(KEY, TINY)
        lowered = api.lower_tree(params, ACFG)
        lp = lowered["layers"]["l0"]["mlp"]["up"]["_plan"]
        g = params["layers"]["l0"]["mlp"]["up"]["w"].shape[0]
        assert lp.w_eff.shape[0] == g and lp.w_eff.ndim == 3

    def test_digital_mode_is_identity(self):
        params = T.lm_init(KEY, TINY)
        assert api.lower_tree(params, AnalogConfig(mode="digital")) \
            is params

    def test_hil_gradients_reach_masters_through_compile(self):
        """compile() inside the differentiated step: STE gradients flow
        through the baked plans to the float masters (incl. the fused
        QKV group)."""
        params = T.lm_init(KEY, TINY)
        run = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        spec = T.lm_module_spec(TINY, params)
        batch = dict(_lm_batch(TINY),
                     labels=_lm_batch(TINY, seed=2)["tokens"])

        def loss(p):
            model = api.compile(spec, p, run)
            return T.lm_loss(model.lower(), batch, TINY, run)[0]

        g = jax.grad(loss)(params)
        gq = np.asarray(g["layers"]["l0"]["attn"]["wq"]["w"])
        assert np.isfinite(gq).all() and np.abs(gq).max() > 0


class TestFusedLowering:
    def test_lower_fused_bit_exact_vs_per_layer(self):
        """One fused dispatch over concatenated columns == the per-layer
        dispatches, bit for bit (column independence of the ADC chain)."""
        cfg = AnalogConfig(noise=NoiseConfig())       # fpn on
        ps = [analog_linear_init(jax.random.PRNGKey(i), 256, 64,
                                 noise=NoiseConfig()) for i in range(3)]
        x = jax.random.normal(KEY, (4, 256)) * 0.3
        from repro.exec.lower import lower_fused
        from repro.exec.run import run_layer

        fused = lower_fused(ps, cfg)
        y = run_layer(fused, x, cfg)
        want = jnp.concatenate(
            [api.apply_linear(p, x, cfg) for p in ps], axis=-1
        )
        np.testing.assert_array_equal(np.asarray(y), np.asarray(want))

    def test_lower_fused_rejects_mixed_input_dims(self):
        from repro.exec.lower import lower_fused

        with pytest.raises(ValueError, match="input dim"):
            lower_fused([_mk(256, 32), _mk(128, 32, seed=1)], ACFG)

    def test_fused_plan_ignored_under_static_calib(self):
        """A fused plan bakes ONE static a_scale (wq's), so a static-calib
        call site must fall back to per-layer lowering rather than
        quantizing k/v with the wrong scale."""
        from repro.models import attention as A

        p = A.attention_init(KEY, 64, 4, 2, 16, noise=NOISELESS)
        # diverge the static scales so misuse would be visible
        p["wk"] = dict(p["wk"], a_scale=p["wk"]["a_scale"] * 7.0)
        x = jax.random.normal(KEY, (2, 8, 64)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None],
                               (2, 8))
        static = ACFG.replace(act_calib="static")
        kw = dict(positions=pos, acfg=static, n_heads=4, n_kv_heads=2,
                  head_dim=16, rope_theta=1e4)
        want, _ = A.attention_apply(p, x, **kw)
        lowered = api.lower_tree(p, ACFG)     # fused under dynamic calib
        got, _ = A.attention_apply(lowered, x, **kw)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_attention_fused_plan_matches_per_layer(self):
        from repro.models import attention as A

        p = A.attention_init(KEY, 64, 4, 2, 16, noise=NOISELESS)
        x = jax.random.normal(KEY, (2, 8, 64)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None],
                               (2, 8))
        kw = dict(positions=pos, acfg=ACFG, n_heads=4, n_kv_heads=2,
                  head_dim=16, rope_theta=1e4)
        want, _ = A.attention_apply(p, x, **kw)
        lowered = api.lower_tree(p, ACFG)
        reset_dispatch_count()
        got, _ = A.attention_apply(lowered, x, **kw)
        assert dispatch_count() == 2          # qkv fused + wo
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


class TestMeshShardedPlans:
    def test_sharding_specs_cover_plan_leaves(self, mesh11):
        """plan_specs_like mirrors the lowered tree's structure, so every
        plan leaf resolves to a NamedSharding (the thing the deleted
        shd_mesh_absent() guard used to make impossible)."""
        params = T.lm_init(KEY, TINY)
        run = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        model = api.compile(T.lm_module_spec(TINY, params), params, run)
        specs = model.sharding_specs()
        shardings = shd.sharding_like(specs, model.lower())
        n_lowered = len(jax.tree.leaves(model.lower()))
        assert len(jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None
        )) >= n_lowered
        for s in jax.tree.leaves(shardings):
            assert hasattr(s, "mesh")

    def test_sharded_compiled_model_bit_exact(self, mesh11):
        """1-device mesh: the sharded pre-lowered tree computes exactly
        the unsharded plan path."""
        params = T.lm_init(KEY, TINY)
        run = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        batch = _lm_batch(TINY)
        model = api.compile(T.lm_module_spec(TINY, params), params, run)
        want, _, _ = model.apply(batch)
        sharded = jax.device_put(
            model.lower(),
            shd.sharding_like(model.sharding_specs(), model.lower()),
        )
        got, _, _ = T.lm_apply(sharded, batch, TINY, run)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_serve_engine_prelowers_under_mesh(self, mesh11):
        """ServeEngine(prelower=True) with a mesh active: pre-lowered
        plans replay (no re-lowering/re-tracing between batches - the
        dispatch counter is trace-time) and outputs are bit-exact vs the
        unsharded engine."""
        from repro.serve.engine import Request, ServeEngine

        run = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        params = T.lm_init(KEY, TINY)
        prompt = np.arange(6) % TINY.vocab_size
        eng = ServeEngine(TINY, run, params, batch_size=2, max_len=32)
        assert "_qkv_plan" in eng.params["layers"]["l0"]["attn"]
        r1 = eng.serve([Request(0, prompt, 4)])[0]
        n1 = dispatch_count()
        r2 = eng.serve([Request(1, prompt, 4)])[0]
        assert dispatch_count() == n1        # pure replay
        np.testing.assert_array_equal(r1.output, r2.output)

    def test_serve_engine_mesh_matches_no_mesh(self):
        from repro.serve.engine import Request, ServeEngine

        run = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        params = T.lm_init(KEY, TINY)
        prompt = np.arange(6) % TINY.vocab_size
        r_plain = ServeEngine(TINY, run, params, batch_size=2, max_len=32) \
            .serve([Request(0, prompt, 4)])[0]
        with shd.use_mesh(jax.make_mesh((1, 1), ("data", "model"))):
            r_mesh = ServeEngine(TINY, run, params, batch_size=2,
                                 max_len=32) \
                .serve([Request(0, prompt, 4)])[0]
        np.testing.assert_array_equal(r_plain.output, r_mesh.output)


class TestMegakernelKnob:
    """CompiledModel.apply(..., megakernel=...) - the api surface of the
    whole-plan megakernel (ISSUE 3)."""

    def _model(self, acfg=None):
        cfg = ECG.ECGConfig()
        params = ECG.ecg_init(jax.random.PRNGKey(0), cfg)
        spec = ECG.ecg_module_spec(cfg, epilogue="relu_shift")
        model = api.compile(spec, params, acfg or AnalogConfig())
        x = jnp.round(
            jax.random.uniform(jax.random.PRNGKey(1), (4, 2, 126)) * 31
        )
        return model, x

    def test_compiled_ecg_chain_is_megakernel_eligible(self):
        model, x = self._model()
        plan = model.lower()
        assert plan.mega is not None
        assert plan.input_domain == "codes"
        assert plan.expected_dispatches == 3

    def test_apply_knob_bit_exact_and_single_dispatch(self):
        model, x = self._model()
        reset_dispatch_count()
        y_auto = model.apply(x)                       # default: "auto"
        assert dispatch_count() == 1                  # ONE analog program
        reset_dispatch_count()
        y_off = model.apply(x, megakernel=False)
        assert dispatch_count() == model.lower().expected_dispatches == 3
        y_on = model.apply(x, megakernel=True)
        np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_off))
        np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_on))

    def test_float_glue_spec_not_packed(self):
        cfg = ECG.ECGConfig()
        params = ECG.ecg_init(jax.random.PRNGKey(0), cfg)
        model = api.compile(ECG.ecg_module_spec(cfg), params, AnalogConfig())
        plan = model.lower()
        assert plan.mega is None and plan.input_domain == "float"

    def test_stack_sharding_specs_cover_mega_leaves(self, mesh11):
        """The stack spec tree mirrors the plan INCLUDING the megakernel
        packing (replicated), so a compiled code-domain model device_puts
        under a mesh like any other plan."""
        model, x = self._model()
        specs = model.sharding_specs()
        plan = model.lower()
        shardings = shd.sharding_like(specs, plan)
        assert len(jax.tree.leaves(shardings)) == len(jax.tree.leaves(plan))
        sharded = jax.device_put(plan, shardings)
        import repro.exec as E2

        np.testing.assert_array_equal(
            np.asarray(E2.run(sharded, ECG._im2col(x, 64, 2))),
            np.asarray(E2.run(plan, ECG._im2col(x, 64, 2))),
        )


class TestDeprecationShims:
    def test_analog_linear_apply_warns_and_matches(self):
        from repro.core.analog import analog_linear_apply

        p = _mk()
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        with pytest.warns(DeprecationWarning, match="analog_linear_apply"):
            y_old = analog_linear_apply(p, x, ACFG)
        np.testing.assert_array_equal(
            np.asarray(y_old), np.asarray(api.apply_linear(p, x, ACFG))
        )

    def test_linear_lower_warns_and_matches(self):
        from repro.models.layers import linear_lower

        p = _mk()
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        with pytest.warns(DeprecationWarning, match="linear_lower"):
            plan_old = linear_lower(p, ACFG)
        plan_new = api.compile(api.linear_spec(256, 64), p, ACFG).lower()
        np.testing.assert_array_equal(
            np.asarray(E.run(plan_old, x)), np.asarray(E.run(plan_new, x))
        )

    def test_ecg_lower_warns_and_matches(self):
        cfg = ECG.ECGConfig()
        params = ECG.ecg_init(jax.random.PRNGKey(0), cfg)
        x = jnp.round(
            jax.random.uniform(jax.random.PRNGKey(1), (4, 2, 126)) * 31
        )
        acfg = AnalogConfig()
        with pytest.warns(DeprecationWarning, match="ecg_lower"):
            plan_old = ECG.ecg_lower(params, acfg, cfg)
        model = api.compile(ECG.ecg_module_spec(cfg), params, acfg)
        np.testing.assert_array_equal(
            np.asarray(ECG.ecg_apply_plan(plan_old, x, cfg)),
            np.asarray(model.apply(x)),
        )

    def test_prelower_tree_warns_and_matches(self):
        from repro.exec.lower import prelower_tree

        p = _mk()
        x = jax.random.normal(KEY, (4, 256)) * 0.2
        with pytest.warns(DeprecationWarning, match="prelower_tree"):
            old = prelower_tree({"layer": p}, ACFG)
        new = api.lower_tree({"layer": p}, ACFG)
        assert "_plan" in old["layer"] and "_plan" in new["layer"]
        np.testing.assert_array_equal(
            np.asarray(api.apply_linear(old["layer"], x, ACFG)),
            np.asarray(api.apply_linear(new["layer"], x, ACFG)),
        )

    def test_internal_paths_do_not_warn(self):
        """The model zoo routes through the api directly - no deprecation
        noise from ordinary forwards."""
        params = T.lm_init(KEY, TINY)
        run = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            T.lm_apply(params, _lm_batch(TINY), TINY, run)
            api.compile(T.lm_module_spec(TINY, params), params, run)
