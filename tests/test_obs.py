"""Tests for ``repro.obs`` (ISSUE 9): host-side tracing (nestable spans,
JSONL export), process-local metrics with percentile summaries and a
JSONL round-trip, plan-derived energy/latency accounting tied to the
paper's 276 us / 192 uJ reference point, the instrumented serve engine
(span tree, plan-cache hit/miss counters, drift probe -> exactly one
hot-swap event) with PROOF that instrumentation adds zero re-lowering
and zero jit-cache growth (``verify.retrace``), the new lint rules
(bare-print / raw-timer), and the telemetry-contract checker behind
``python -m repro.obs --serve-smoke``.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.exec as E
from repro import calib, obs
from repro.configs.base import ArchConfig, RunConfig
from repro.core.analog import AnalogConfig, analog_linear_init
from repro.core.energy import SystemModel
from repro.core.noise import NOISELESS
from repro.models import ecg as ECG
from repro.models import transformer as T
from repro.obs import energy as obs_energy
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.serve.engine import Request, ServeEngine
from repro.verify.lint import lint_source
from repro.verify.retrace import assert_no_retrace

KEY = jax.random.PRNGKey(0)
SPLIT_CFG = AnalogConfig(noise=NOISELESS, signed_input="split")


# ---------------------------------------------------------------------------
# trace: spans, nesting, events, collectors
# ---------------------------------------------------------------------------


class TestTrace:
    def test_span_outside_collector_still_times(self):
        with obs_trace.span("solo") as sp:
            pass
        assert sp.dur_us >= 0.0
        assert obs_trace.active_trace() is None

    def test_nesting_builds_slash_paths(self):
        with obs_trace.collect("t") as tr:
            with obs_trace.span("outer"):
                with obs_trace.span("inner"):
                    obs_trace.event("ping", x=1)
        assert tr.span_paths() == {"outer", "outer/inner"}
        (ev,) = tr.events_named("ping")
        assert ev["path"] == "outer/inner/ping" and ev["meta"] == {"x": 1}
        # inner span recorded before outer (close order)
        names = [e["name"] for e in tr.spans()]
        assert names == ["inner", "outer"]

    def test_span_meta_via_add(self):
        with obs_trace.collect() as tr:
            with obs_trace.span("s", a=1) as sp:
                sp.add(b=2)
        (rec,) = tr.spans("s")
        assert rec["meta"] == {"a": 1, "b": 2}
        assert rec["dur_us"] >= 0.0

    def test_collect_nests_and_restores(self):
        with obs_trace.collect("outer") as t1:
            with obs_trace.collect("inner") as t2:
                obs_trace.event("e")
                assert obs_trace.active_trace() is t2
            assert obs_trace.active_trace() is t1
        assert t2.events_named("e") and not t1.events_named("e")

    def test_begin_end_pair(self):
        tr = obs_trace.begin("driver")
        obs_trace.event("tick")
        got = obs_trace.end(tr)
        assert got is tr and tr.events_named("tick")
        assert obs_trace.active_trace() is None

    def test_jsonl_round_trip(self, tmp_path):
        with obs_trace.collect("rt") as tr:
            with obs_trace.span("a"):
                obs_trace.event("b", k="v")
        p = tmp_path / "t.jsonl"
        tr.dump_jsonl(str(p))
        recs = [json.loads(line) for line in p.read_text().splitlines()]
        assert recs[0]["rec"] == "trace" and recs[0]["name"] == "rt"
        assert {r["rec"] for r in recs[1:]} == {"span", "event"}

    def test_timeit_matches_gate_shape_and_records(self):
        calls = []

        def f():
            calls.append(1)
            return 0

        with obs_trace.collect() as tr:
            us = obs_trace.timeit(f, iters=4, warmup=2, blocks=3,
                                  label="unit")
        # warmup + blocks*iters, every call blocked
        assert len(calls) == 2 + 3 * 4
        assert us >= 0.0
        (ev,) = tr.events_named("timeit")
        assert ev["meta"]["label"] == "unit"
        assert ev["meta"]["us_per_call"] == pytest.approx(us, abs=0.001)


# ---------------------------------------------------------------------------
# metrics: counters/gauges/histograms + JSONL round-trip
# ---------------------------------------------------------------------------


class TestMetrics:
    def setup_method(self):
        obs_metrics.reset_metrics()

    def test_counter_gauge(self):
        obs_metrics.counter("c").inc()
        obs_metrics.counter("c").inc(4)
        obs_metrics.gauge("g").set(2.5)
        assert obs_metrics.counter("c").value == 5
        assert obs_metrics.gauge("g").value == 2.5

    def test_histogram_percentiles(self):
        h = obs_metrics.histogram("h")
        for v in range(1, 101):                 # 1..100
            h.record(float(v))
        s = h.summary()
        assert s["count"] == 100
        assert s["p50"] == 50.0 and s["p95"] == 95.0 and s["p99"] == 99.0
        assert s["min"] == 1.0 and s["max"] == 100.0

    def test_type_collision_raises(self):
        obs_metrics.counter("x")
        with pytest.raises(TypeError):
            obs_metrics.histogram("x")

    def test_jsonl_round_trip(self, tmp_path):
        obs_metrics.counter("hits").inc(3)
        obs_metrics.gauge("uj").set(192.0)
        h = obs_metrics.histogram("lat_us")
        for v in (10.0, 20.0, 30.0):
            h.record(v)
        p = tmp_path / "m.jsonl"
        obs_metrics.export_jsonl(str(p))
        back = obs_metrics.import_jsonl(str(p))
        assert back.get("hits").value == 3
        assert back.get("uj").value == 192.0
        assert back.get("lat_us").summary() == h.summary()


# ---------------------------------------------------------------------------
# energy: compiled plans -> paper's Table-1 numbers
# ---------------------------------------------------------------------------


def _ecg_code_plan():
    cfg = ECG.ECGConfig()
    params = ECG.ecg_init(jax.random.PRNGKey(3), cfg)
    from repro.exec.lower import lower_stack

    return lower_stack(
        [params["conv"], params["fc1"], params["fc2"]],
        AnalogConfig(mode="analog_fast"),
        epilogues=["relu_shift", "relu_shift", "none"],
        flatten_outs=[True, False, False], input_domain="codes",
    )


class TestEnergy:
    def test_ecg_plan_hits_paper_latency(self):
        rep = obs_energy.energy_report(_ecg_code_plan())
        assert rep["analog_passes"] == 4        # conv, fc1 x2 chunks, fc2
        assert rep["us_per_sample"] == pytest.approx(276.0)
        assert rep["us_vs_paper"] == pytest.approx(1.0)
        # on-ASIC energy within a few percent of the paper's 192 uJ
        assert rep["uj_per_sample"] == pytest.approx(192.0, rel=0.05)

    def test_plan_works_match_expected_dispatch_semantics(self):
        # a split-encoded float-domain plan costs 2 passes per vector
        p = analog_linear_init(KEY, 256, 64, noise=NOISELESS)
        plan = E.lower(p, SPLIT_CFG)
        (w,) = obs_energy.plan_layer_works(plan)
        assert w.passes_per_vector == 2
        rep = obs_energy.energy_report(plan, model=SystemModel())
        assert rep["analog_passes"] == 4        # 2 row chunks x split pair

    def test_record_sets_gauges_and_event(self):
        obs_metrics.reset_metrics()
        with obs_trace.collect() as tr:
            rep = obs_energy.record(_ecg_code_plan(), prefix="e")
        assert obs_metrics.gauge("e.us_per_sample").value == \
            pytest.approx(rep["us_per_sample"])
        assert tr.events_named("e")
        out = obs_energy.format_report(rep, title="ecg")
        assert "276" in out and "us/sample" in out


# ---------------------------------------------------------------------------
# serve engine telemetry + drift + retrace pin
# ---------------------------------------------------------------------------


def _smoke_engine(**kw):
    cfg = ArchConfig("t-obs", "dense", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab_size=256)
    params = T.lm_init(KEY, cfg)
    run_cfg = RunConfig(analog=AnalogConfig(mode="analog_fast"))
    eng = ServeEngine(cfg, run_cfg, params, batch_size=2, max_len=32, **kw)
    return cfg, eng


def _reqs(cfg, n, uid0=0, max_new=4):
    prompt = np.arange(6) % cfg.vocab_size
    return [Request(uid0 + i, prompt, max_new) for i in range(n)]


class TestServeTelemetry:
    def test_batch_emits_span_tree_and_metrics(self):
        obs_metrics.reset_metrics()
        with obs_trace.collect("serve") as tr:
            cfg, eng = _smoke_engine()
            eng.serve(_reqs(cfg, 3))
        paths = tr.span_paths()
        assert "serve.compile" in paths
        assert "serve.compile/api.compile" in paths
        assert "serve.batch" in paths
        assert "serve.batch/serve.prefill" in paths
        assert "serve.batch/serve.decode" in paths
        # 3 requests at batch_size=2 -> 2 refill groups
        refills = tr.events_named("serve.refill")
        assert [e["meta"]["size"] for e in refills] == [2, 1]
        assert tr.events_named("serve.energy")
        reg = obs_metrics.registry()
        assert reg.get("exec.dispatches").value > 0
        assert reg.get("serve.prefill_us").summary()["count"] == 2
        assert reg.get("serve.decode_us").summary()["count"] > 0
        assert reg.get("serve.queue_us").summary()["count"] == 3
        assert reg.get("serve.request_us").summary()["count"] == 3
        occ = reg.get("serve.batch_occupancy").summary()
        assert occ["count"] == 2 and occ["max"] == 1.0 and occ["min"] == 0.5

    def test_dispatch_counter_is_trace_time_only(self):
        obs_metrics.reset_metrics()
        p = analog_linear_init(KEY, 256, 64, noise=NOISELESS)
        plan = E.lower(p, SPLIT_CFG)
        x = jax.random.normal(KEY, (4, 256)) * 0.2

        f = jax.jit(E.run)
        jax.block_until_ready(f(plan, x))
        warm = obs_metrics.counter("exec.dispatches").value
        assert warm > 0
        jax.block_until_ready(f(plan, x))       # cached replay: no bump
        assert obs_metrics.counter("exec.dispatches").value == warm

    def test_plan_cache_hit_miss_counters(self, tmp_path):
        obs_metrics.reset_metrics()
        cache = str(tmp_path / "plan.npz")
        with obs_trace.collect() as tr:
            cfg, _ = _smoke_engine(plan_cache=cache)       # miss: lowers
            _smoke_engine(plan_cache=cache)                # hit: loads
        reg = obs_metrics.registry()
        assert reg.get("serve.plan_cache.miss").value == 1
        assert reg.get("serve.plan_cache.hit").value == 1
        statuses = [e["meta"]["status"]
                    for e in tr.events_named("serve.plan_cache")]
        assert statuses == ["miss", "hit"]

    def test_forced_drift_emits_exactly_one_hot_swap(self):
        obs_metrics.reset_metrics()
        cfg = ArchConfig("t-obs-drift", "dense", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)
        params = T.lm_init(KEY, cfg)
        run_cfg = RunConfig(analog=AnalogConfig(mode="analog_fast"))
        spec = T.lm_module_spec(cfg, params)
        chips = calib.model_chips(spec, params, KEY)
        snap = calib.calibrate_model(spec, params, KEY, chips=chips,
                                     offset_repeats=16, gain_repeats=2)
        mon = calib.DriftMonitor(chips, snap, threshold_lsb=0.5)
        eng = ServeEngine(cfg, run_cfg, params, batch_size=2, max_len=32,
                          calibration=snap, drift_monitor=mon)
        with obs_trace.collect() as tr:
            eng.serve(_reqs(cfg, 1))            # stable: probe only
            for i, c in enumerate(chips.values()):
                c.apply_drift(jax.random.fold_in(KEY, 70 + i), 2.0)
            eng.serve(_reqs(cfg, 1, uid0=1))    # drifted: probe + swap
        probes = tr.events_named("drift.probe")
        assert len(probes) == 2
        assert probes[0]["meta"]["lsb"] <= 0.5 < probes[1]["meta"]["lsb"]
        assert len(tr.events_named("drift.hot_swap")) == 1
        reg = obs_metrics.registry()
        assert reg.get("drift.hot_swap").value == 1
        assert reg.get("serve.hot_swap").value == 1
        assert reg.get("drift.lsb").summary()["count"] == 2
        assert "serve.hot_swap" in tr.span_paths()

    def test_instrumentation_adds_zero_retrace(self):
        """The acceptance pin: serving WITH an active collector does no
        lowering work and grows no jit cache vs the warm path - the
        telemetry is entirely host-side."""
        cfg, eng = _smoke_engine()
        eng.serve(_reqs(cfg, 2))                # warm every executable
        cache0 = (eng.prefill._cache_size(), eng.decode._cache_size())
        uid = [100]

        def serve_instrumented():
            with obs_trace.collect():
                uid[0] += 2
                eng.serve(_reqs(cfg, 2, uid0=uid[0]))

        diags = assert_no_retrace(serve_instrumented, replays=3,
                                  label="serve+obs")
        assert diags == ()
        assert (eng.prefill._cache_size(),
                eng.decode._cache_size()) == cache0


# ---------------------------------------------------------------------------
# compile-path instrumentation
# ---------------------------------------------------------------------------


class TestCompileSpan:
    def test_compile_records_span_and_lowerings(self):
        from repro import api

        cfg = ECG.ECGConfig()
        params = ECG.ecg_init(jax.random.PRNGKey(1), cfg)
        spec = ECG.ecg_module_spec(cfg, epilogue="relu_shift")
        with obs_trace.collect() as tr:
            api.compile(spec, params,
                        AnalogConfig(mode="analog_fast")).lower()
        (sp,) = tr.spans("api.compile")
        assert sp["meta"]["spec"] == spec.name
        assert sp["meta"]["lowerings"] >= 1

    def test_verify_diagnostics_surface_as_events(self):
        from repro import api
        from repro.api.module import LayerSpec, ModuleSpec
        from repro.verify import VerifyError

        # per-layer dims match their params (so lowering succeeds) but
        # the declared chain is broken: a emits 64, b expects 128
        pa = analog_linear_init(KEY, 256, 64, noise=NOISELESS)
        pb = analog_linear_init(KEY, 128, 32, noise=NOISELESS)
        spec = ModuleSpec(name="bad", kind="stack", layers=(
            LayerSpec("a", 256, 64), LayerSpec("b", 128, 32),
        ))
        with obs_trace.collect() as tr:
            with pytest.raises(VerifyError):
                api.compile(spec, {"a": pa, "b": pb}, SPLIT_CFG,
                            verify=True)
        evs = tr.events_named("verify.diagnostic")
        assert evs and all("rule" in e["meta"] for e in evs)


# ---------------------------------------------------------------------------
# lint rules: bare-print / raw-timer
# ---------------------------------------------------------------------------


class TestObsLintRules:
    def test_bare_print_flagged_in_repro(self):
        src = "def f():\n    print('hi')\n"
        rules = {f.rule for f in lint_source(src, "src/repro/serve/x.py")}
        assert "bare-print" in rules

    def test_allow_comment_suppresses(self):
        src = "def f():\n    print('hi')  # verify: allow-bare-print\n"
        assert not lint_source(src, "src/repro/serve/x.py")

    def test_obs_dir_and_main_and_outside_exempt(self):
        src = "print('hi')\n"
        assert not lint_source(src, "src/repro/obs/trace.py")
        assert not lint_source(src, "src/repro/verify/__main__.py")
        assert not lint_source(src, "benchmarks/run.py")

    def test_raw_timer_flagged(self):
        src = "import time\nt = time.perf_counter()\n"
        rules = {f.rule for f in lint_source(src, "src/repro/launch/t.py")}
        assert "raw-timer" in rules
        assert not lint_source(src, "examples/demo.py")


# ---------------------------------------------------------------------------
# report rendering + required-telemetry contract
# ---------------------------------------------------------------------------


class TestReport:
    def _records(self):
        obs_metrics.reset_metrics()
        with obs_trace.collect("r") as tr:
            with obs_trace.span("a"):
                obs_trace.event("ev", k=1)
            obs_metrics.counter("hits").inc(2)
            obs_metrics.histogram("lat_us").record(120.0)
        return obs_report.records_of(tr, obs_metrics.registry())

    def test_render_sections(self):
        out = obs_report.render(self._records())
        assert "spans" in out and "a" in out
        assert "hits" in out and "lat_us" in out

    def test_dump_and_load(self, tmp_path):
        obs_metrics.reset_metrics()
        with obs_trace.collect("d") as tr:
            obs_metrics.counter("c").inc()
        p = tmp_path / "run.jsonl"
        obs_report.dump_run(str(p), tr, obs_metrics.registry())
        recs = obs_report.load(str(p))
        assert any(r["rec"] == "trace" for r in recs)
        assert any(r["rec"] == "counter" and r["name"] == "c"
                   for r in recs)

    def test_required_missing(self):
        recs = self._records()
        missing = obs_report.required_missing(
            recs, span_paths=("a", "zz"), events=("ev",),
            counters=("hits", "nope"), histograms=("lat_us",),
        )
        assert "span:zz" in missing and "counter:nope" in missing
        assert len(missing) == 2
        assert obs_report.required_missing(
            recs, span_paths=("a",), events=("ev",), counters=("hits",),
            histograms=("lat_us",),
        ) == []
