"""Batched serving with the analog backend: prefill + decode engine.

    PYTHONPATH=src python examples/serve_batch.py --arch stablelm-3b \
        --requests 12 --max-new 16 [--mode analog_fast] [--mesh]

Demonstrates the inference-engine substrate (the `decode_*` dry-run cells
at smoke scale): request batching, left-padded prefill, per-sequence
stopping, greedy/categorical sampling - with the model's parameter
matmuls on emulated analog tiles if requested.  The engine goes through
the `repro.api` front door: the model is compiled ONCE (attention QKV
fused into one dispatch group) and the jitted steps replay the baked
plans - also under an active mesh (``--mesh``), where the plan leaves
shard by the same logical axes as the weights they were baked from.
"""
import argparse
import contextlib

import numpy as np

from repro import configs, obs
from repro.configs.base import RunConfig
from repro.core.analog import AnalogConfig
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", default="digital",
                    choices=["digital", "analog_faithful", "analog_fast"])
    ap.add_argument("--mesh", action="store_true",
                    help="serve under a (data, model) host mesh with "
                         "sharded pre-lowered plans")
    a = ap.parse_args(argv)

    cfg = configs.get_smoke(a.arch)
    if not cfg.embed_inputs:
        raise SystemExit(f"{a.arch} backbone takes frontend embeddings - "
                         "pick a token-input arch for this example")
    run = RunConfig(analog=AnalogConfig(mode=a.mode)) if a.mode != "digital" \
        else RunConfig()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    mesh_ctx = contextlib.nullcontext()
    if a.mesh:
        n = len(jax.devices())
        mesh_ctx = shd.use_mesh(jax.make_mesh((n, 1), ("data", "model")))
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 12)),
                max_new_tokens=a.max_new)
        for i in range(a.requests)
    ]
    obs.reset_metrics()
    with obs.collect("serve-batch") as tr, mesh_ctx:
        engine = ServeEngine(cfg, run, params, batch_size=a.batch,
                             max_len=128)
        with obs.span("serve.all") as sp:
            done = engine.serve(reqs)
        dt = sp.dur_us / 1e6
    total_new = sum(len(r.output) for r in done)
    print(f"arch={a.arch} mode={a.mode}: served {len(done)} requests, "
          f"{total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s on CPU emulation)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[:6]={r.prompt[:6].tolist()} -> "
              f"out[:8]={r.output[:8].tolist()}")
    print("\n=== end-of-run obs report ===")
    print(obs.report.render(
        obs.report.records_of(tr, obs.metrics.registry())
    ))


if __name__ == "__main__":
    main()
