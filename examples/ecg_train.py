"""End-to-end driver: the paper's showcase, start to finish (paper §III-IV).

    PYTHONPATH=src:. python examples/ecg_train.py [--epochs 40] [--fast]

Pipeline (all stages implemented, none stubbed):
  synthetic 2-channel ECG records (the competition set is private)
    -> FPGA preprocessing chain (derivative, max-min pool 32, 5-bit quant)
    -> Fig.-6 CDNN declared once (`ecg_module_spec`) and compiled through
       the `repro.api` front door onto the analog backend
    -> hardware-in-the-loop training (noisy analog fwd, float bwd;
       training re-compiles per step, eval replays one CompiledModel)
    -> standalone-inference evaluation (deterministic, avg-pool readout)
    -> Table-1 energy/latency accounting for the trained model

Paper reference points: detection (93.7 +- 0.7)% @ (14.0 +- 1.0)% FP,
276 us / 1.56 mJ per inference.
"""
import argparse

from benchmarks.ecg_accuracy import run
from repro import obs
from repro.core.energy import LayerWork, SystemModel, battery_lifetime_years
from repro.models.ecg import ECGConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n-train", type=int, default=0,
                    help="override train-set size (0 = preset)")
    ap.add_argument("--n-test", type=int, default=0)
    a = ap.parse_args(argv)

    kw = dict(n_train=600, n_test=250, epochs=10) if a.fast else dict(
        epochs=a.epochs
    )
    if a.n_train:
        kw["n_train"] = a.n_train
    if a.n_test:
        kw["n_test"] = a.n_test
    with obs.collect("ecg-train") as tr:
        print("=== HIL training on the analog backend (mock-mode noise) "
              "===")
        with obs.span("ecg.train.analog"):
            r = run(mode="analog_faithful", **kw)
        print(f"\nanalog HIL: detection {r['detection_rate']*100:.1f}% @ "
              f"{r['false_positive_rate']*100:.1f}% FP  "
              f"[paper: 93.7% @ 14.0%]  ({r['train_s']:.0f}s)")

        print("\n=== digital software baseline (same data/model) ===")
        with obs.span("ecg.train.digital"):
            rd = run(mode="digital", verbose=False, **kw)
        print(f"digital:   detection {rd['detection_rate']*100:.1f}% @ "
              f"{rd['false_positive_rate']*100:.1f}% FP")

        print("\n=== deployment cost on the BSS-2 mobile system ===")
        ecg = ECGConfig()
        m = SystemModel()
        rep = m.report([LayerWork(k=lw.k, n=lw.n)
                        for lw in ecg.layer_works()])
        print(f"per inference: {rep['time_s']*1e6:.0f} us, "
              f"{rep['energy_total_j']*1e3:.2f} mJ total "
              f"({rep['energy_asic_j']*1e6:.0f} uJ on-ASIC)  "
              f"[paper: 276 us, 1.56 mJ, 192 uJ]")
        print(f"CR2032 @ 2-min monitoring interval: "
              f"{battery_lifetime_years(rep['energy_total_j']):.1f} years "
              f"[paper: ~5 years]")

        # end-of-run obs report: the SAME accounting, but derived from
        # the compiled plan of the trained weights (paper §II-A
        # standalone inference: the code-domain single program) rather
        # than from config geometry
        from repro import api
        from repro.core.analog import AnalogConfig
        from repro.models.ecg import ecg_module_spec

        plan = api.compile(
            ecg_module_spec(ecg, epilogue="relu_shift"), r["params"],
            AnalogConfig(mode="analog_fast"),
        ).lower()
        erep = obs.energy.record(plan, prefix="ecg.energy")

    print("\n=== end-of-run obs report (trained plan) ===")
    print(obs.energy.format_report(erep, title="ecg"))
    print()
    print(obs.report.render(
        obs.report.records_of(tr, obs.metrics.registry())
    ))


if __name__ == "__main__":
    main()
