"""Train an LM with every parameter matmul on emulated BSS-2 analog tiles -
the paper's §V claim ("arbitrarily large models by time-multiplexing analog
tiles") exercised end-to-end with HIL/QAT training.

    PYTHONPATH=src python examples/lm_analog_train.py \
        --arch qwen3-moe-30b-a3b --steps 60

Uses the smoke-size variant of the chosen architecture (full configs are a
pod-scale job; see launch/dryrun.py for the 256/512-chip lowering).  Trains
the same model twice - digital and analog_faithful - and compares loss
curves: the analog run converges despite W6A5 quantization, saturating
8-bit ADCs and fixed-pattern noise, which is the paper's §III-B result.

The train step goes through the `repro.api` front door: every step
re-compiles the declared analog layers from the float masters inside the
gradient (`api.compile` in train_step.py), which IS the hardware-in-the-
loop scheme - the STE quantizers in the lowering carry the gradients back.
"""
import argparse

import numpy as np

from repro import configs
from repro.launch.train import train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=configs.ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    a = ap.parse_args(argv)

    print(f"=== {a.arch} (smoke config), digital baseline ===")
    dig = train_loop(a.arch, smoke=True, steps=a.steps, batch=a.batch,
                     seq_len=a.seq_len, mode="digital",
                     log_every=max(a.steps // 5, 1))
    print(f"\n=== {a.arch} (smoke config), analog_faithful (HIL/QAT) ===")
    ana = train_loop(a.arch, smoke=True, steps=a.steps, batch=a.batch,
                     seq_len=a.seq_len, mode="analog_faithful",
                     log_every=max(a.steps // 5, 1))

    d0, d1 = np.mean(dig["losses"][:5]), np.mean(dig["losses"][-5:])
    a0, a1 = np.mean(ana["losses"][:5]), np.mean(ana["losses"][-5:])
    print("\n=== summary ===")
    print(f"digital: {d0:.3f} -> {d1:.3f}")
    print(f"analog:  {a0:.3f} -> {a1:.3f}")
    print("analog training converges through the quantized, noisy, "
          "saturating substrate (paper §III-B / Fig. 8)."
          if a1 < 0.9 * a0 else
          "WARNING: analog run did not converge - inspect noise config")


if __name__ == "__main__":
    main()
