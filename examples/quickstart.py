"""Quickstart: the analog execution backend in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. declares + compiles one analog linear through the ``repro.api`` front
   door (spec -> compile -> apply) and shows the BSS-2 datapath (5-bit
   events, 6-bit weights, chunked saturating 8-bit ADC),
2. compiles a whole LM and swaps it between digital / analog_faithful /
   analog_fast - same CompiledModel contract at every scale,
3. prints what the inference would cost on the real BSS-2 mobile system
   (Table-1-calibrated energy model).
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.configs.base import ArchConfig, RunConfig
from repro.core import BSS2, AnalogConfig, NoiseConfig
from repro.core.analog import analog_linear_init
from repro.core.energy import LayerWork, SystemModel
from repro.models import transformer as T


def main(argv=None):
    # ------------------------------------------------- 1. one analog linear
    # declare once -> compile -> apply: the execution contract of the repo
    key = jax.random.PRNGKey(0)
    params = analog_linear_init(key, 256, 128, noise=NoiseConfig())
    x = jax.random.normal(key, (4, 256)) * 0.3

    spec = api.linear_spec(256, 128)
    y_digital = api.compile(spec, params, AnalogConfig(mode="digital")).apply(x)
    y_analog = api.compile(spec, params, AnalogConfig()).apply(x)
    rel = float(jnp.abs(y_analog - y_digital).max()
                / jnp.abs(y_digital).max())
    print(f"[1] analog vs digital linear: rel err {rel:.3f} "
          f"(W{BSS2.w_bits}A{BSS2.a_bits} + fixed-pattern noise)")

    # --------------------------------------------- 2. a whole LM, one switch
    cfg = ArchConfig("demo", "dense", n_layers=2, d_model=128, n_heads=4,
                     n_kv_heads=2, d_ff=256, vocab_size=512)
    lm = T.lm_init(jax.random.PRNGKey(1), cfg)
    lm_spec = T.lm_module_spec(cfg, lm)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, 512)}
    for mode in ("digital", "analog_faithful", "analog_fast"):
        run = RunConfig(analog=AnalogConfig(mode=mode)) \
            if mode != "digital" else RunConfig()
        # compile bakes every analog layer once (attention QKV fused into
        # one dispatch group); apply replays the plans
        model = api.compile(lm_spec, lm, run)
        logits, _, _ = model.apply(batch)
        print(f"[2] mode={mode:16s} logits[0,0,:3] = "
              f"{jnp.asarray(logits[0, 0, :3]).tolist()}")

    # ------------------------------- 3. what would this cost on the real chip?
    shapes = [(128, 512)] * 8          # eight BSS-2-tile-sized matmuls
    m = SystemModel()
    r = m.report([LayerWork(k=k_, n=n_) for k_, n_ in shapes])
    print(f"[3] 8-tile inference on the BSS-2 mobile system: "
          f"{r['time_s']*1e6:.0f} us, {r['energy_total_j']*1e3:.2f} mJ "
          f"({r['ops_per_s']/1e6:.0f} MOp/s)")
    print("    (constants calibrated to paper Table 1; see benchmarks/)")


if __name__ == "__main__":
    main()
